"""Paper App. H.3: pre-processing cost and its amortization, plus selection
throughput microbenchmarks (the jit-compiled greedy engines).

The SGE-bank section is the PR-over-PR perf trajectory for the selection hot
path (recorded in ``BENCH_selection.json`` by ``benchmarks.run``):

  * ``sge_seq_full``   — the legacy path: one dispatch per run, O(n²) full
                         gain vector per step (``gains_at`` disabled).
  * ``sge_vmap_gather``— the fused path: whole bank in one XLA program,
                         O(n·s) candidate-gather gains per step.
  * ``sge_gram_free``  — the fused path over features only (no Gram matrix
                         anywhere): the route that scales past the O(n²)
                         memory wall (n=32768 Gram would be 4.3 GB fp32).

``BENCH_FAST=1`` keeps small-n cases only (CI smoke); the Pallas gram-free
kernel is always exercised once in interpret mode so kernel regressions show
up on every push, not only under pytest.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import (
    MiloPreprocessor,
    get_gram_free,
    gram_matrix,
    greedy,
    greedy_importance,
    lazy_greedy,
    sge,
    stochastic_greedy,
)
from repro.core.gram_free import make_gram_free_facility_location
from repro.core.greedy import stochastic_candidate_count
from repro.core.similarity import normalize_rows
from repro.core.submodular import facility_location, graph_cut
from repro.data.datasets import GaussianMixtureDataset


def _timeit(fn, reps: int = 3) -> float:
    fn()  # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def _features(n: int, d: int = 64, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


def _bench_sge_bank(rows: list[str], verbose: bool, fast: bool) -> None:
    """Before/after for the tentpole: sequential full-gains vs vmapped
    candidate-gather vs gram-free, at n ∈ {2048, 8192, 32768}."""
    n_subsets = 2
    eps = 0.01
    sizes = (2048,) if fast else (2048, 8192, 32768)
    seq_full_max_n = 8192  # the legacy path's K + per-step O(n²) beyond this
                           # is exactly the wall this PR removes
    for n in sizes:
        z = _features(n)
        k = max(1, n // 20)
        s = stochastic_candidate_count(n, k, eps)
        meta = f"k={k} s={s} n_subsets={n_subsets}"
        timings: dict[str, float] = {}

        if n <= seq_full_max_n:
            K = gram_matrix(z)
            # the pre-PR path: gains_at disabled -> full O(n²) gain vector
            # per step, one dispatch per bank entry
            fn_full = dataclasses.replace(facility_location, gains_at=None)
            timings["seq_full"] = _timeit(
                lambda: jax.block_until_ready(
                    sge(fn_full, K, k, jax.random.PRNGKey(0),
                        n_subsets=n_subsets, eps=eps, vmapped=False)
                ),
                reps=1 if n > 2048 else 2,
            )
            rows.append(csv_row(f"preprocess/sge_seq_full_n{n}",
                                timings["seq_full"] * 1e6, meta))
            if verbose:
                print(rows[-1])

            timings["vmap_gather"] = _timeit(
                lambda: jax.block_until_ready(
                    sge(facility_location, K, k, jax.random.PRNGKey(0),
                        n_subsets=n_subsets, eps=eps, vmapped=True)
                ),
            )
            speedup = timings["seq_full"] / max(timings["vmap_gather"], 1e-9)
            rows.append(csv_row(f"preprocess/sge_vmap_gather_n{n}",
                                timings["vmap_gather"] * 1e6,
                                f"{meta} speedup_vs_seq_full={speedup:.1f}x"))
            if verbose:
                print(rows[-1])
            del K

        # gram-free: no (n, n) Gram anywhere — the only route at n=32768+.
        # Same set function (facility location) as the columns above, so the
        # comparison isolates gram-freedom, not a cheaper objective.
        zn = normalize_rows(z)
        fn_gf = make_gram_free_facility_location()
        timings["gram_free"] = _timeit(
            lambda: jax.block_until_ready(
                sge(fn_gf, zn, k, jax.random.PRNGKey(0),
                    n_subsets=n_subsets, eps=eps, vmapped=True)
            ),
        )
        gram_mb = n * n * 4 / 2**20
        feat_mb = z.size * 4 / 2**20
        rows.append(csv_row(
            f"preprocess/sge_gram_free_n{n}", timings["gram_free"] * 1e6,
            f"{meta} mem_mb={feat_mb:.1f} gram_would_be_mb={gram_mb:.0f}"))
        if verbose:
            print(rows[-1])


def _bench_lazy_importance(rows: list[str], verbose: bool, fast: bool) -> None:
    """Lazy gain reuse on the WRE full-greedy FL pass (ISSUE 3 tentpole).

    The eager engine contracts all n ground rows for every one of its n
    steps; the lazy engine's traced counter records what it actually
    contracted (budget rows on a lazy step, n on a fallback recompute), so
    ``eval_reduction`` is exact even at sizes where the eager pass is not
    worth running (n=8192 would be ~35 PFLOP-equivalent of row evals).
    """
    d = 32
    cases = ((512, 64, True),) if fast else (
        (1024, 128, True),      # eager A/B at a tractable size
        (8192, 256, False),     # acceptance row: counter-only reduction
    )
    for n, budget, run_eager in cases:
        zn = normalize_rows(_features(n, d=d))
        fn = make_gram_free_facility_location()
        res = None

        def one():
            nonlocal res
            res = lazy_greedy(fn, zn, n, budget=budget)
            jax.block_until_ready(res.rows_evaluated)

        t_lazy = _timeit(one, reps=1)
        rows_eval = np.asarray(res.rows_evaluated)
        eager_evals = n * n
        lazy_evals = n + int(rows_eval.sum())  # + init full evaluation
        reduction = eager_evals / lazy_evals
        full_steps = int((rows_eval == n).sum())
        meta = (f"budget={budget} eval_reduction={reduction:.1f}x "
                f"full_recomputes={full_steps}/{n}")
        if run_eager:
            t_eager = _timeit(
                lambda: greedy(fn, zn, n).gains.block_until_ready(), reps=1
            )
            rows.append(csv_row(f"preprocess/importance_fl_eager_n{n}",
                                t_eager * 1e6, f"d={d}"))
            if verbose:
                print(rows[-1])
            meta += f" speedup_vs_eager={t_eager / max(t_lazy, 1e-9):.1f}x"
        rows.append(csv_row(f"preprocess/importance_fl_lazy_n{n}",
                            t_lazy * 1e6, meta))
        if verbose:
            print(rows[-1])


def _bench_sharded(rows: list[str], verbose: bool, fast: bool) -> None:
    """Row-sharded selection vs the single-device path (only meaningful on a
    multi-device platform; on CPU force one with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  Forced host
    "devices" share the physical cores, so the value measured here is the
    memory split (n/ndev feature rows per device) and trajectory equality,
    not wall-clock speedup."""
    if jax.device_count() < 2:
        return
    from repro.core import (
        make_sharded_gram_free,
        sharded_greedy_importance,
        sharded_lazy_greedy,
        sharded_sge,
    )
    from repro.distributed.sharding import selection_mesh

    mesh = selection_mesh()
    ndev = jax.device_count()
    n = 512 if fast else 4096
    n -= n % ndev
    k = max(1, n // 20)
    zn = normalize_rows(_features(n))
    fn1 = make_gram_free_facility_location()
    fns = make_sharded_gram_free("facility_location", n_shards=ndev)
    key = jax.random.PRNGKey(0)

    bank1 = bank8 = None

    def run_single():
        nonlocal bank1
        bank1 = jax.block_until_ready(sge(fn1, zn, k, key, n_subsets=2))

    def run_sharded():
        nonlocal bank8
        bank8 = jax.block_until_ready(
            sharded_sge(fns, zn, k, key, n_subsets=2, mesh=mesh))

    t1 = _timeit(run_single, reps=1)
    t8 = _timeit(run_sharded, reps=1)
    same = bool(np.array_equal(np.asarray(bank1), np.asarray(bank8)))
    rows.append(csv_row(
        f"preprocess/sge_sharded_n{n}_dev{ndev}", t8 * 1e6,
        f"k={k} single_device_us={t1 * 1e6:.0f} trajectories_equal={same} "
        f"rows_per_device={n // ndev}"))
    if verbose:
        print(rows[-1])

    if fast:
        fnd1 = get_gram_free("disparity_min")
        fnd8 = make_sharded_gram_free("disparity_min", n_shards=ndev)
        t1 = _timeit(lambda: greedy_importance(fnd1, zn).block_until_ready(),
                     reps=1)
        t8 = _timeit(lambda: sharded_greedy_importance(
            fnd8, zn, mesh=mesh).block_until_ready(), reps=1)
        rows.append(csv_row(
            f"preprocess/importance_sharded_n{n}_dev{ndev}", t8 * 1e6,
            f"single_device_us={t1 * 1e6:.0f} rows_per_device={n // ndev}"))
        if verbose:
            print(rows[-1])

    # lazy + sharded composed (ISSUE 4 tentpole): the WRE full-greedy FL
    # pass with cached gains corrected over touched rows only, inside
    # shard_map.  The traced counter is the acceptance evidence — the eager
    # ring engine would contract all n ground rows on every one of its n
    # steps, so eval_reduction = n² / (n + Σ rows_evaluated) is exact even
    # where the eager pass is not worth timing.
    n_lz = 512 if fast else 8192
    n_lz -= n_lz % ndev
    budget = max(1, n_lz // 8)
    zl = normalize_rows(_features(n_lz, d=32))
    fl8 = make_sharded_gram_free("facility_location", n_shards=ndev)
    res = None

    def run_lazy_sharded():
        nonlocal res
        res = sharded_lazy_greedy(fl8, zl, n_lz, budget=budget, mesh=mesh)
        jax.block_until_ready(res.rows_evaluated)

    t_lz = _timeit(run_lazy_sharded, reps=1)
    rows_eval = np.asarray(res.rows_evaluated)
    reduction = (n_lz * n_lz) / (n_lz + int(rows_eval.sum()))
    full_steps = int((rows_eval == n_lz).sum())
    rows.append(csv_row(
        f"preprocess/importance_fl_lazy_sharded_n{n_lz}_dev{ndev}",
        t_lz * 1e6,
        f"budget={budget} eval_reduction={reduction:.1f}x "
        f"full_recomputes={full_steps}/{n_lz} rows_per_device={n_lz // ndev}"))
    if verbose:
        print(rows[-1])

    # Two-level gather budget (ISSUE 5 satellite): each lazy step gathers —
    # and psums across the mesh — only the smallest pow2 level covering the
    # rows that actually moved, instead of the full budget-sized block.
    # Trajectories are bit-identical; the payload counter (rows_evaluated
    # records the level gathered) is the psum-reduction evidence.
    res2 = None

    def run_lazy_two_level():
        nonlocal res2
        res2 = sharded_lazy_greedy(fl8, zl, n_lz, budget=budget, mesh=mesh,
                                   two_level=True)
        jax.block_until_ready(res2.rows_evaluated)

    t_lz2 = _timeit(run_lazy_two_level, reps=1)
    rows2 = np.asarray(res2.rows_evaluated)
    lazy1 = rows_eval[rows_eval < n_lz]
    lazy2 = rows2[rows2 < n_lz]
    payload_red = lazy1.sum() / max(lazy2.sum(), 1)
    identical = bool(np.array_equal(np.asarray(res.indices),
                                    np.asarray(res2.indices)))
    rows.append(csv_row(
        f"preprocess/importance_fl_lazy2_sharded_n{n_lz}_dev{ndev}",
        t_lz2 * 1e6,
        f"budget={budget} psum_payload_reduction={payload_red:.1f}x "
        f"mean_gather_rows={lazy2.mean():.1f} (single-level={budget}) "
        f"indices_identical={identical}"))
    if verbose:
        print(rows[-1])


def run(verbose: bool = True) -> list[str]:
    fast = os.environ.get("BENCH_FAST") == "1"
    rows = []
    # full preprocessing wall time vs dataset size (default path: bucketed,
    # vmapped bank, candidate-gather gains)
    for n in (1000,) if fast else (1000, 4000):
        ds = GaussianMixtureDataset(n=n, n_classes=10, dim=32, seed=0)
        pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=4, gram_block=1024)
        t0 = time.perf_counter()
        md = pre.preprocess(ds.features(), ds.y, jax.random.PRNGKey(0))
        dt = time.perf_counter() - t0
        rows.append(csv_row(f"preprocess/full_n{n}", dt * 1e6,
                            f"k={md.k} per_sample_us={dt/n*1e6:.1f}"))
        if verbose:
            print(rows[-1])

    # jit-compiled greedy engine throughput (whole-run-on-device; the
    # beyond-paper replacement for submodlib's per-element host loop)
    z = _features(2048)
    K = gram_matrix(z)
    for name, fn in (("facility_location", facility_location), ("graph_cut", graph_cut)):
        k = 205
        dt = _timeit(lambda: greedy(fn, K, k).indices.block_until_ready())
        rows.append(csv_row(f"preprocess/greedy_{name}_n2048_k205", dt * 1e6,
                            f"per_element_us={dt/k*1e6:.1f}"))
        if verbose:
            print(rows[-1])

    s = stochastic_candidate_count(2048, 205, 0.01)
    dt = _timeit(lambda: stochastic_greedy(
        facility_location, K, 205, jax.random.PRNGKey(1), s=s
    ).indices.block_until_ready())
    rows.append(csv_row("preprocess/stochastic_greedy_n2048_k205", dt * 1e6,
                        f"candidates_per_step={s}"))
    if verbose:
        print(rows[-1])
    del K

    _bench_sge_bank(rows, verbose, fast)
    _bench_lazy_importance(rows, verbose, fast)
    _bench_sharded(rows, verbose, fast)

    # Pallas gram-free FL kernel smoke (interpret mode off-TPU): exercises the
    # fused-similarity kernel on every benchmark run, including CI
    from repro.kernels.fl_gains import ops as fl_ops

    interpret = jax.default_backend() != "tpu"
    zn = normalize_rows(_features(256, d=32))
    c = jnp.zeros((256,))
    dt = _timeit(lambda: jax.block_until_ready(
        fl_ops.fl_gains_gram_free(zn, zn[:128], c, block_i=128, block_j=128,
                                  interpret=interpret)
    ), reps=1)
    rows.append(csv_row("preprocess/fl_gains_gram_free_pallas_n256",
                        dt * 1e6, f"interpret={interpret} n_cand=128"))
    if verbose:
        print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
