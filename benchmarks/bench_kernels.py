"""Kernel microbenchmarks: oracle-path throughput on CPU plus interpret-mode
validation timing.  (Pallas compiled timings require a TPU; the roofline
terms for the kernels' target shapes come from launch/roofline.py.)"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.kernels.fl_gains import ops as fl_ops
from repro.kernels.fl_gains.ref import fl_gains_ref
from repro.kernels.flash_attention.ref import gqa_attention_ref
from repro.kernels.similarity.ref import similarity_ref


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(verbose: bool = True) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)

    # similarity: 2048x2048 Gram, d=768 (DINO CLS width)
    z = jnp.asarray(rng.normal(size=(2048, 768)).astype(np.float32))
    f = jax.jit(lambda a: similarity_ref(a, a))
    dt = _time(f, z)
    flops = 2 * 2048 * 2048 * 768
    rows.append(csv_row("kernel/similarity_ref_2048x768", dt * 1e6,
                        f"gflops={flops/dt/1e9:.1f}"))

    # fl gains: n=4096 candidates=4096
    K = jnp.asarray(rng.uniform(size=(4096, 4096)).astype(np.float32))
    c = jnp.asarray(rng.uniform(size=(4096,)).astype(np.float32))
    f = jax.jit(fl_gains_ref)
    dt = _time(f, K, c)
    rows.append(csv_row("kernel/fl_gains_ref_4096", dt * 1e6,
                        f"gbps={(K.size*4/dt)/1e9:.1f}"))

    # flash attention oracle: B2 H8 S512 D64 GQA2
    q = jnp.asarray(rng.normal(size=(2, 8, 512, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 2, 512, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 2, 512, 64)).astype(np.float32))
    f = jax.jit(lambda a, b, cc: gqa_attention_ref(a, b, cc))
    dt = _time(f, q, k, v)
    attn_flops = 4 * 2 * 8 * 512 * 512 * 64
    rows.append(csv_row("kernel/flash_attention_ref_b2h8s512", dt * 1e6,
                        f"gflops={attn_flops/dt/1e9:.1f}"))

    # ssd chunk oracle (jamba hot-spot): B2 H16 L256 P64 N128
    from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
    x2 = jnp.asarray(rng.normal(size=(256, 16, 64)).astype(np.float32))
    a2 = jnp.asarray(rng.uniform(0.8, 1.0, size=(256, 16)).astype(np.float32))
    b2 = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    c2 = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    h2 = jnp.zeros((16, 128, 64), jnp.float32)
    f = jax.jit(ssd_chunk_ref)
    dt = _time(f, x2, a2, b2, c2, h2)
    ssd_flops = 2 * 256 * 256 * (128 + 16 * 64)  # scores + weighted sum approx
    rows.append(csv_row("kernel/ssd_chunk_ref_L256", dt * 1e6,
                        f"gflops={ssd_flops/dt/1e9:.1f}"))

    # kernel-free landmark selection vs exact kernel selection (future-work impl)
    import time as _time_mod
    from repro.core import facility_location, gram_matrix, greedy
    from repro.core.feature_submodular import feature_greedy_select
    z2 = jnp.asarray(rng.normal(size=(2048, 64)).astype(np.float32))
    t0 = _time_mod.perf_counter()
    Kz = gram_matrix(z2); greedy(facility_location, Kz, 128).indices.block_until_ready()
    t_exact = _time_mod.perf_counter() - t0
    t0 = _time_mod.perf_counter()
    feature_greedy_select(jax.random.PRNGKey(0), z2, 128).indices.block_until_ready()
    t_feat = _time_mod.perf_counter() - t0
    rows.append(csv_row("kernel/feature_vs_kernel_selection_n2048_k128",
                        t_feat * 1e6, f"exact_s={t_exact:.2f} feature_s={t_feat:.2f} "
                        f"mem_ratio={2048/512}"))

    # interpret-mode Pallas correctness-path timing (not a perf number; shows
    # the validation path stays usable in CI)
    Ksmall = K[:512, :512]
    csmall = c[:512]
    dt = _time(lambda a, b: fl_ops.fl_gains(a, b, interpret=True), Ksmall, csmall, reps=2)
    rows.append(csv_row("kernel/fl_gains_pallas_interpret_512", dt * 1e6, "validation-path"))
    if verbose:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
