"""Paper Fig. 5: (a) SGE vs WRE vs fixed subsets across set functions;
(b) early convergence of SGE(graph-cut) vs WRE(disparity-min); plus the
curriculum combining both (Fig. 14).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv_row, train_with_selector
from repro.core import CurriculumConfig, MiloPreprocessor, MiloSelector
from repro.data.datasets import GaussianMixtureDataset


def _selector(md, kappa, epochs, seed=0):
    return MiloSelector(md, CurriculumConfig(total_epochs=epochs, kappa=kappa, R=1), seed=seed)


def run(verbose: bool = True) -> list[str]:
    # Fig. 5's regime needs a genuinely hard task at a tiny budget (the paper
    # uses CIFAR100 at 5%): many overlapping classes, 50% boundary samples.
    ds = GaussianMixtureDataset(n=2400, n_classes=20, dim=24, seed=0, sep=3.0,
                                tail_frac=0.5)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    epochs = 48
    rows = []

    pre = MiloPreprocessor(subset_fraction=0.05, n_sge_subsets=6, gram_block=512)
    md = pre.preprocess(feats, labs, jax.random.PRNGKey(0))

    names = {"sge_graphcut": 1.0, "wre_dispmin": 0.0, "curriculum_k1_6": 1 / 6}
    seeds = (0, 1, 2)
    outs = {n: [] for n in names}
    for name, kappa in names.items():
        for seed in seeds:
            outs[name].append(train_with_selector(
                feats, labs, _selector(md, kappa=kappa, epochs=epochs, seed=seed),
                epochs=epochs, seed=seed,
                test_x=ds.features()[te], test_y=ds.y[te]))
        mean_final = sum(o["final_acc"] for o in outs[name]) / len(seeds)
        mean_early = sum(o["curve"][1]["acc"] for o in outs[name]) / len(seeds)
        rows.append(csv_row(
            f"exploration/{name}",
            sum(o["train_time"] for o in outs[name]) / len(seeds) * 1e6,
            f"final={mean_final:.4f} early_acc_ep1={mean_early:.4f}"))
        if verbose:
            print(rows[-1])

    def mean(name, key):
        if key == "final":
            return sum(o["final_acc"] for o in outs[name]) / len(seeds)
        return sum(o["curve"][1]["acc"] for o in outs[name]) / len(seeds)

    # paper claims (3-seed means): SGE(gc) converges faster EARLY; WRE(dm)
    # better FINAL; curriculum >= both endpoints.
    early_sge, early_wre = mean("sge_graphcut", "early"), mean("wre_dispmin", "early")
    rows.append(csv_row("exploration/claim_sge_early", 0,
                        f"sge={early_sge:.4f} wre={early_wre:.4f} holds={early_sge >= early_wre - 0.02}"))
    final_cur = mean("curriculum_k1_6", "final")
    final_ends = max(mean("sge_graphcut", "final"), mean("wre_dispmin", "final"))
    rows.append(csv_row("exploration/claim_curriculum_best", 0,
                        f"curriculum={final_cur:.4f} best_endpoint={final_ends:.4f} "
                        f"holds={final_cur >= final_ends - 0.02}"))
    if verbose:
        print(rows[-2])
        print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
