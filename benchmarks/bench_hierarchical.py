"""Hierarchical partition-then-refine selection at ground-set sizes where
the flat pass cannot run (ISSUE 9 tentpole).

The tracked row ``selection/hier_fl_n1048576`` selects k=1024 from n=2^20
rows with ``random_blocks`` partitions of 1024: peak working memory is the
*partition* size (1024·d rows gram-free), not the ground set, and total
work is Σ_c O(n_c·k_c) + O(union·k) instead of the flat pass's O(n²·d)
per-step gains — which at n=2^20 would be ~10^15 FLOPs/step and is not
runnable.  The flat wall is therefore *projected* from a measured flat run
at a tractable n (per-step gains scale O(n·d) and steps scale with k ∝ n,
so wall ∝ n²); the projection basis is recorded in the derived field.

``BENCH_FAST=1`` shrinks to n=2^14 (CI smoke; row name keeps the
``selection/hier_`` prefix the smoke job greps for).
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core.milo import hierarchical_select
from repro.core.greedy import refine
from repro.core.gram_free import make_gram_free_facility_location
from repro.core.similarity import normalize_rows


def _features(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, d)).astype(np.float32)


def run(verbose: bool = True) -> list[str]:
    import jax

    fast = os.environ.get("BENCH_FAST") == "1"
    rows: list[str] = []
    d = 32
    if fast:
        n, block, k, n_flat = 2**14, 1024, 64, 2**12
    else:
        n, block, k, n_flat = 2**20, 1024, 1024, 2**14
    rf = 2

    # flat reference at a tractable size (same objective, same k/n ratio,
    # lazy gains) — the basis for the n² wall projection
    k_flat = max(1, (n_flat * k) // n)
    z_flat = normalize_rows(np.asarray(_features(n_flat, d)))
    fn = make_gram_free_facility_location()
    t0 = time.perf_counter()
    res_flat = refine(fn, z_flat, k_flat, lazy_budget=max(1, n_flat // 8))
    jax.block_until_ready(res_flat.indices)
    t_flat = time.perf_counter() - t0
    flat_proj = t_flat * (n / n_flat) ** 2
    rows.append(csv_row(
        f"selection/flat_fl_n{n_flat}", t_flat * 1e6,
        f"k={k_flat} lazy d={d} (projection basis for hier row)"))
    if verbose:
        print(rows[-1])

    feats = _features(n, d)
    t0 = time.perf_counter()
    idx, info = hierarchical_select(
        feats, k, partition="random_blocks", block_size=block,
        refine_factor=rf, gram_free=True, return_info=True)
    t_hier = time.perf_counter() - t0
    assert len(np.unique(idx)) == k
    peak_rows = int(info["peak_partition_rows"])
    peak_mb = peak_rows * d * 4 / 2**20
    flat_mb = n * d * 4 / 2**20  # flat pass must hold (and scan) all rows
    rows.append(csv_row(
        f"selection/hier_fl_n{n}", t_hier * 1e6,
        f"k={k} blocks={info['n_partitions']} rf={rf} "
        f"union={info['union_size']} peak_part_rows={peak_rows} "
        f"peak_part_mb={peak_mb:.1f} flat_mb={flat_mb:.0f} "
        f"flat_proj_s={flat_proj:.0f} "
        f"speedup_vs_flat_proj={flat_proj / max(t_hier, 1e-9):.0f}x"))
    if verbose:
        print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
