"""Benchmark master: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Set ``BENCH_FAST=1`` to run a
reduced subset (CI smoke).

  bench_set_functions  — Fig. 4 (set-function composition)
  bench_exploration    — Fig. 5 (SGE vs WRE vs curriculum)
  bench_training       — Fig. 6 / Tab. 5,7 (MILO vs baselines, speedup/deg)
  bench_tuning         — Fig. 7 / Tab. 9,10 (hparam tuning + Kendall-tau)
  bench_ablations      — Tab. 1,2,13,14 (hardness, kappa, R)
  bench_preprocess     — App. H.3 (preprocess cost, greedy throughput)
  bench_kernels        — kernel microbenches
"""
from __future__ import annotations

import os
import sys
import time


def main() -> None:
    from benchmarks import (
        bench_ablations,
        bench_exploration,
        bench_kernels,
        bench_preprocess,
        bench_set_functions,
        bench_training,
        bench_tuning,
    )

    fast = os.environ.get("BENCH_FAST") == "1"
    modules = [
        ("set_functions", bench_set_functions),
        ("exploration", bench_exploration),
        ("training", bench_training),
        ("tuning", bench_tuning),
        ("ablations", bench_ablations),
        ("preprocess", bench_preprocess),
        ("kernels", bench_kernels),
    ]
    if fast:
        modules = [m for m in modules if m[0] in ("preprocess", "kernels")]

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    for name, mod in modules:
        t1 = time.time()
        try:
            rows = mod.run(verbose=False)
            for r in rows:
                print(r, flush=True)
            print(f"# {name} done in {time.time()-t1:.1f}s", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
    print(f"# total {time.time()-t0:.1f}s, failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
