"""Benchmark master: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and merges every measured row
into a tracked JSON trajectory file so the perf trajectory is
machine-readable across PRs, not just printed: ``bench_training``'s rows
land in ``BENCH_training.json`` (the training/tuning hot-path trajectory),
everything else in ``BENCH_selection.json``.  Override the paths with
``BENCH_TRAINING_JSON`` / ``BENCH_JSON``; ``BENCH_JSON=0`` disables ALL
writes.  Set ``BENCH_FAST=1`` to run a reduced subset (CI smoke); pass
module names as argv to run a subset, e.g.
``python -m benchmarks.run preprocess kernels``.

  bench_set_functions  — Fig. 4 (set-function composition)
  bench_exploration    — Fig. 5 (SGE vs WRE vs curriculum)
  bench_training       — Fig. 6 / Tab. 5,7 (MILO vs baselines, speedup/deg)
  bench_tuning         — Fig. 7 / Tab. 9,10 (hparam tuning + Kendall-tau)
  bench_ablations      — Tab. 1,2,13,14 (hardness, kappa, R)
  bench_preprocess     — App. H.3 (preprocess cost, greedy/SGE throughput)
  bench_kernels        — kernel microbenches
  bench_serving        — warm MiloServer vs N cold sessions (concurrent tuning)
  bench_hierarchical   — partition→refine selection at flat-infeasible n
  bench_multihost      — two-process selection vs single-process (bit-identity)
"""
from __future__ import annotations

import datetime
import json
import os
import sys
import time

DEFAULT_JSON_PATH = "BENCH_selection.json"
DEFAULT_TRAINING_JSON_PATH = "BENCH_training.json"


def parse_row(row: str) -> tuple[str, dict] | None:
    """``name,us_per_call,derived`` -> (name, record); None for non-rows."""
    if row.startswith("#"):
        return None
    parts = row.split(",", 2)
    if len(parts) != 3:
        return None
    name, us, derived = parts
    try:
        return name, {"us_per_call": float(us), "derived": derived}
    except ValueError:
        return None


def write_json(rows: list[str], path: str, *, fmt: str = "bench-selection") -> None:
    """Merge measured rows into the JSON trajectory file keyed by benchmark
    name, so partial runs (module subsets, BENCH_FAST) refresh their own
    entries without clobbering the rest.  Each record carries backend/fast
    metadata so a CPU smoke row is never mistaken for a TPU trajectory
    point."""
    try:
        import jax

        backend = jax.default_backend()
        device_count = jax.device_count()
    except Exception:  # benchmarks ran, so this is near-impossible; be safe
        backend = "unknown"
        device_count = 0
    if device_count > 1:
        # only rows emitted by the sharded benches get the axis stamp below
        from repro.core.sharded import AXIS as shard_axis
    else:
        shard_axis = None
    fast = os.environ.get("BENCH_FAST") == "1"
    doc: dict = {"format": fmt, "version": 1, "benchmarks": {}}
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
            if isinstance(prev.get("benchmarks"), dict):
                doc["benchmarks"] = prev["benchmarks"]
        except (json.JSONDecodeError, OSError):
            pass  # unreadable trajectory file: start fresh rather than crash
    stamp = datetime.datetime.now(datetime.timezone.utc).isoformat(timespec="seconds")
    for row in rows:
        parsed = parse_row(row)
        if parsed is None:
            continue
        name, rec = parsed
        rec["measured_at"] = stamp
        rec["backend"] = backend
        rec["device_count"] = device_count
        if shard_axis is not None and "sharded" in name:
            rec["shard_axis"] = shard_axis
        if fast:
            rec["bench_fast"] = True
        doc["benchmarks"][name] = rec
    doc["updated"] = stamp
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def main(argv: list[str] | None = None) -> None:
    from benchmarks import (
        bench_ablations,
        bench_exploration,
        bench_hierarchical,
        bench_kernels,
        bench_multihost,
        bench_preprocess,
        bench_serving,
        bench_set_functions,
        bench_training,
        bench_tuning,
    )

    argv = sys.argv[1:] if argv is None else argv
    fast = os.environ.get("BENCH_FAST") == "1"
    # third field: which tracked trajectory file the module's rows merge into
    modules = [
        ("set_functions", bench_set_functions, "selection"),
        ("exploration", bench_exploration, "selection"),
        ("training", bench_training, "training"),
        ("serving", bench_serving, "training"),
        ("tuning", bench_tuning, "selection"),
        ("ablations", bench_ablations, "selection"),
        ("preprocess", bench_preprocess, "selection"),
        ("kernels", bench_kernels, "selection"),
        ("hierarchical", bench_hierarchical, "selection"),
        ("multihost", bench_multihost, "selection"),
    ]
    if argv:
        known = {name for name, _, _ in modules}
        unknown = [a for a in argv if a not in known]
        if unknown:
            raise SystemExit(f"unknown benchmark modules {unknown}; available: {sorted(known)}")
        modules = [m for m in modules if m[0] in argv]
    elif fast:
        modules = [m for m in modules if m[0] in ("preprocess", "kernels")]

    print("name,us_per_call,derived")
    t0 = time.time()
    failures = 0
    rows_by_target: dict[str, list[str]] = {"selection": [], "training": []}
    for name, mod, target in modules:
        t1 = time.time()
        try:
            rows = mod.run(verbose=False)
            rows_by_target[target].extend(rows)
            for r in rows:
                print(r, flush=True)
            print(f"# {name} done in {time.time()-t1:.1f}s", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"# {name} FAILED: {type(e).__name__}: {e}", flush=True)
    json_path = os.environ.get("BENCH_JSON", DEFAULT_JSON_PATH)
    training_path = os.environ.get("BENCH_TRAINING_JSON",
                                   DEFAULT_TRAINING_JSON_PATH)
    if json_path != "0":
        if rows_by_target["selection"]:
            write_json(rows_by_target["selection"], json_path)
            print(f"# wrote {json_path}")
        if rows_by_target["training"] and training_path != "0":
            write_json(rows_by_target["training"], training_path,
                       fmt="bench-training")
            print(f"# wrote {training_path}")
    print(f"# total {time.time()-t0:.1f}s, failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
