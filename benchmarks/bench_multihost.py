"""Multi-host selection: two coordinated processes vs one process exposing
the same devices.

The row tracked in ``BENCH_selection.json``:

  * ``selection/multihost_fl_n*_p2`` — gram-free facility-location greedy
    over the global ``sel`` mesh, run by TWO real jax processes (1 CPU
    device each, gloo collectives) launched through
    ``repro.testing.faults.launch_hosts``.  The derived fields assert the
    tentpole property alongside the timing: ``bit_identical_vs_single``
    compares indices AND gain bit patterns against a single-process run
    forcing 2 local devices (the same logical program, no coordination
    service), and ``hosts_agree`` checks both processes observed identical
    replicated results.  ``single_us`` is the single-process time for the
    same work, so the trajectory shows what cross-process dispatch costs.

``BENCH_FAST=1`` shrinks n/reps (CI smoke: the multihost-smoke job runs
this module explicitly and greps for the row).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import csv_row
from repro.testing.faults import launch_hosts

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: each child exposes ONE CPU device; the global mesh is 2 devices
CHILD_ENV = {"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "XLA_FLAGS": ""}

BENCH_SCRIPT = r"""
import json, sys, time
out, n, k, reps = sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
from repro.distributed import multihost
multihost.initialize()
import jax, numpy as np, jax.numpy as jnp
from repro.core import make_sharded_gram_free, sharded_greedy
from repro.core.similarity import normalize_rows
from repro.distributed.sharding import selection_mesh

assert jax.device_count() == 2, jax.device_count()
rng = np.random.default_rng(0)
z = normalize_rows(jnp.asarray(rng.normal(size=(n, 16)).astype(np.float32)))
mesh = selection_mesh()
fn = make_sharded_gram_free("facility_location", n_shards=2)
res = sharded_greedy(fn, z, k, mesh=mesh)          # warm the jit cache
jax.block_until_ready(res.gains)
t0 = time.perf_counter()
for _ in range(reps):
    res = sharded_greedy(fn, z, k, mesh=mesh)
    jax.block_until_ready(res.gains)
us = (time.perf_counter() - t0) / reps * 1e6
payload = {
    "us": us,
    "indices": np.asarray(res.indices).tolist(),
    "gains_bits": np.asarray(res.gains, np.float32).view(np.uint32).tolist(),
}
with open(f"{out}.{jax.process_index()}.json", "w") as f:
    json.dump(payload, f)
print("BENCH_DONE", jax.process_index())
"""


def _run_single(out: str, n: int, k: int, reps: int, timeout: float) -> dict:
    env = dict(os.environ)
    for var in ("MILO_COORDINATOR", "MILO_NUM_PROCESSES", "MILO_PROCESS_ID"):
        env.pop(var, None)
    env.update(CHILD_ENV)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    r = subprocess.run(
        [sys.executable, "-c", BENCH_SCRIPT, out, str(n), str(k), str(reps)],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT,
        timeout=timeout,
    )
    if r.returncode != 0:  # pragma: no cover
        raise RuntimeError(f"single-process reference failed: {r.stderr[-2000:]}")
    with open(f"{out}.0.json") as f:
        return json.load(f)


def _bench_two_process_selection(rows: list[str], verbose: bool, fast: bool) -> None:
    import tempfile

    n = 256 if fast else 1024
    k = 24 if fast else 64
    reps = 2 if fast else 5
    tmp = tempfile.mkdtemp()
    out2 = os.path.join(tmp, "two")

    t0 = time.perf_counter()
    results = launch_hosts(
        BENCH_SCRIPT, [out2, n, k, reps], num_processes=2,
        env=CHILD_ENV, cwd=REPO_ROOT, timeout=600.0)
    wall = time.perf_counter() - t0
    for r in results:
        if r.returncode != 0:  # pragma: no cover
            raise RuntimeError(
                f"process {r.process_id} failed: {r.stderr[-2000:]}")

    with open(f"{out2}.0.json") as f:
        p0 = json.load(f)
    with open(f"{out2}.1.json") as f:
        p1 = json.load(f)
    hosts_agree = (p0["indices"] == p1["indices"]
                   and p0["gains_bits"] == p1["gains_bits"])

    single = _run_single(os.path.join(tmp, "one"), n, k, reps, 600.0)
    identical = (p0["indices"] == single["indices"]
                 and p0["gains_bits"] == single["gains_bits"])

    rows.append(csv_row(
        f"selection/multihost_fl_n{n}_p2", p0["us"],
        f"k={k} reps={reps} single_us={single['us']:.1f} "
        f"hosts_agree={hosts_agree} bit_identical_vs_single={identical} "
        f"launch_wall_s={wall:.1f}"))
    if verbose:
        print(rows[-1])


def run(verbose: bool = True) -> list[str]:
    fast = os.environ.get("BENCH_FAST") == "1"
    rows: list[str] = []
    _bench_two_process_selection(rows, verbose, fast)
    return rows


if __name__ == "__main__":
    run()
