"""Paper Fig. 6 / Tables 5,7: MILO vs baselines for single-model training —
speedup vs accuracy-degradation tradeoff at multiple subset fractions, incl.
the model-dependent baselines whose *selection cost sits on the training
critical path* (the paper's core argument).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import accuracy, csv_row, init_mlp, mlp_logits, train_with_selector
from repro.core import MiloPreprocessor
from repro.data.datasets import GaussianMixtureDataset
from repro.selection import build_selector


def run(verbose: bool = True) -> list[str]:
    ds = GaussianMixtureDataset(n=2000, n_classes=8, dim=24, seed=1)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    tx, ty = ds.features()[te], ds.y[te]
    epochs = 40
    rows = []

    # FULL skyline
    full = train_with_selector(feats, labs, build_selector("full", n=len(tr)),
                               epochs=epochs, test_x=tx, test_y=ty)
    rows.append(csv_row("training/full", full["train_time"] * 1e6,
                        f"acc={full['final_acc']:.4f} speedup=1.00"))
    if verbose:
        print(rows[-1])

    # proxy per-sample gradients for model-dependent baselines: last-layer
    # gradient of a probe model — recomputed at each refresh (their real cost)
    probe = init_mlp(jax.random.PRNGKey(9), feats.shape[1], int(labs.max()) + 1)

    def grad_fn():
        logits = mlp_logits(probe, jnp.asarray(feats))
        p = jax.nn.softmax(logits)
        onehot = jax.nn.one_hot(jnp.asarray(labs), logits.shape[-1])
        return np.asarray(p - onehot)  # last-layer grad proxy (CORDS-style)

    def val_grad_fn():
        logits = mlp_logits(probe, jnp.asarray(ds.features()[va]))
        p = jax.nn.softmax(logits)
        onehot = jax.nn.one_hot(jnp.asarray(ds.y[va]), logits.shape[-1])
        return np.asarray(p - onehot).mean(0)

    for frac in (0.1, 0.3):
        k = int(len(tr) * frac)
        pre_t0 = time.perf_counter()
        pre = MiloPreprocessor(subset_fraction=frac, n_sge_subsets=6, gram_block=512)
        md = pre.preprocess(feats, labs, jax.random.PRNGKey(0))
        preprocess_s = time.perf_counter() - pre_t0
        selectors = {
            "milo": build_selector("milo", metadata=md, total_epochs=epochs,
                                   kappa=1 / 6, R=1),
            "random": build_selector("random", n=len(tr), k=k, seed=0),
            "adaptive_random": build_selector("adaptive_random", n=len(tr), k=k,
                                              R=1, seed=0),
            "milo_fixed": build_selector("milo_fixed", features=feats, k=k),
            "craigpb_R10": build_selector("craig_pb", grad_fn=grad_fn, k=k, R=10),
            "gradmatchpb_R10": build_selector("gradmatch_pb", grad_fn=grad_fn, k=k, R=10),
            "glister_R10": build_selector("glister", grad_fn=grad_fn,
                                          val_grad_fn=val_grad_fn, k=k, R=10),
        }
        for name, sel in selectors.items():
            out = train_with_selector(feats, labs, sel, epochs=epochs,
                                      test_x=tx, test_y=ty)
            speedup = full["train_time"] / out["train_time"]
            degradation = full["final_acc"] - out["final_acc"]
            extra = f" preprocess_s={preprocess_s:.2f}" if name == "milo" else ""
            rows.append(csv_row(
                f"training/{name}/frac{frac}", out["train_time"] * 1e6,
                f"acc={out['final_acc']:.4f} speedup={speedup:.2f} "
                f"degradation={degradation:.4f} select_s={out['select_time']:.3f}{extra}"))
            if verbose:
                print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
