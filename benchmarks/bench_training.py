"""Paper Fig. 6 / Tables 5,7: MILO vs baselines for single-model training —
speedup vs accuracy-degradation tradeoff at multiple subset fractions, incl.
the model-dependent baselines whose *selection cost sits on the training
critical path* (the paper's core argument).

Plus the training/tuning hot-path rows tracked in ``BENCH_training.json``:

  * ``training/fused_superstep`` — the device-resident engine
    (``Trainer(fused=True, superstep=32)``: one scan dispatch per 32
    steps, state donated, batches gathered on device) vs the per-batch
    step loop, steps/sec on the classifier workload, with a final-params
    allclose check between the two paths.
  * ``tuning/hyperband_batched`` — hyperband rungs evaluated as ONE
    vmapped dispatch over the rung's stacked lr leaves
    (``batched_objective`` + ``stack_configs``) vs the sequential
    per-trial loop, with best-config/trial-stream identity checks.

``BENCH_FAST=1`` runs only those two sections at reduced sizes (CI smoke).
"""
from __future__ import annotations

import functools
import os
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import accuracy, csv_row, init_mlp, mlp_logits, train_with_selector
from repro.core import MiloPreprocessor
from repro.data.datasets import GaussianMixtureDataset
from repro.data.pipeline import Pipeline
from repro.models.classifier import nesterov_update, weighted_nll
from repro.selection import build_selector
from repro.train.trainer import Trainer, TrainerConfig
from repro.tuning.tuner import RandomSearch, hyperband, stack_configs


# ---------------------------------------------------------------------------
# fused superstep engine vs per-batch step loop
# ---------------------------------------------------------------------------

class _BenchState(NamedTuple):
    params: dict
    mom: dict
    step: jax.Array


def _bench_step(state: _BenchState, batch: dict):
    loss, g = jax.value_and_grad(weighted_nll)(
        state.params, batch["x"], batch["y"], batch["weights"]
    )
    params, mom = nesterov_update(state.params, state.mom, g, 0.05)
    return _BenchState(params, mom, state.step + 1), {"loss": loss}


_BENCH_STEP = jax.jit(_bench_step)


def _bench_fused_training(rows: list[str], verbose: bool, fast: bool) -> None:
    n, d, n_classes = (1024, 16, 4) if fast else (2048, 24, 8)
    k = 512 if fast else 1024
    batch_size = 32
    superstep = 32
    epochs = 5 if fast else 20
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(n, d)).astype(np.float32)
    labs = rng.integers(0, n_classes, size=n).astype(np.int64)
    sel = build_selector("random", n=n, k=k, seed=0)

    def make_batch(idx: np.ndarray) -> dict:
        return {"x": feats[idx], "y": labs[idx]}

    def init_state() -> _BenchState:
        params = init_mlp(jax.random.PRNGKey(0), d, n_classes)
        return _BenchState(params, jax.tree.map(jnp.zeros_like, params),
                           jnp.zeros((), jnp.int32))

    tcfg = TrainerConfig(epochs=epochs, log_every_steps=0)
    # prefetch=False: the session's loop path runs these cheap host slices
    # unthreaded, so the baseline measures the real per-batch dispatch loop,
    # not prefetch-queue overhead
    pipe_loop = Pipeline(make_batch, sel, batch_size, seed=0, prefetch=False)
    pipe_fused = Pipeline(None, sel, batch_size, seed=0,
                          arrays={"x": feats, "y": labs})

    def loop_trainer() -> Trainer:
        return Trainer(_BENCH_STEP, pipe_loop, tcfg)

    def fused_trainer() -> Trainer:
        return Trainer(_BENCH_STEP, pipe_fused, tcfg,
                       fused=True, superstep=superstep)

    # warm every program (step, segment shapes) outside the timed region
    loop_trainer().fit(init_state(), resume=False)
    fused_trainer().warm_fused(init_state())

    def timed(make_trainer):
        best, state = np.inf, None
        for _ in range(2):   # best-of-2: a 2-core box is noisy at this scale
            t0 = time.perf_counter()
            state = make_trainer().fit(init_state(), resume=False)
            jax.block_until_ready(state.params)
            best = min(best, time.perf_counter() - t0)
        return best, state

    t_loop, state_loop = timed(loop_trainer)
    t_fused, state_fused = timed(fused_trainer)

    steps = (k // batch_size) * epochs
    allclose = all(
        np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
        for a, b in zip(jax.tree.leaves(state_loop.params),
                        jax.tree.leaves(state_fused.params))
    )
    rows.append(csv_row(
        "training/fused_superstep", t_fused * 1e6,
        f"steps_per_sec_fused={steps / t_fused:.0f} "
        f"steps_per_sec_loop={steps / t_loop:.0f} "
        f"speedup={t_loop / t_fused:.2f}x superstep={superstep} "
        f"n={n} batch={batch_size} steps={steps} params_allclose={allclose}"))
    if verbose:
        print(rows[-1])

    # divergence-guard overhead on the healthy fused path: the guard is a
    # jnp.where fused into the scan body plus one metrics column, so the
    # acceptance bar (ISSUE 8) is <= 2% slowdown vs the unguarded engine
    from repro.health.guard import GuardPolicy

    gcfg = TrainerConfig(epochs=epochs, log_every_steps=0,
                         guard=GuardPolicy(action="skip_step"))

    def guarded_trainer() -> Trainer:
        return Trainer(_BENCH_STEP, pipe_fused, gcfg,
                       fused=True, superstep=superstep)

    guarded_trainer().warm_fused(init_state())

    def timed_once(make_trainer):
        t0 = time.perf_counter()
        state = make_trainer().fit(init_state(), resume=False)
        jax.block_until_ready(state.params)
        return time.perf_counter() - t0, state

    # interleaved best-of-3: back-to-back pairs cancel the machine drift a
    # sequential best-of would read as guard overhead at this tiny scale
    t_base, t_guard, state_guard = np.inf, np.inf, None
    for _ in range(3):
        t_b, _ = timed_once(fused_trainer)
        t_g, state_guard = timed_once(guarded_trainer)
        t_base, t_guard = min(t_base, t_b), min(t_guard, t_g)
    identical = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state_fused.params),
                        jax.tree.leaves(state_guard.params))
    )
    overhead = t_guard / t_base - 1.0
    rows.append(csv_row(
        "training/guarded_superstep", t_guard * 1e6,
        f"steps_per_sec_guarded={steps / t_guard:.0f} "
        f"overhead_vs_unguarded={overhead * 100:+.1f}% "
        f"within_2pct={overhead <= 0.02} params_bit_identical={identical}"))
    if verbose:
        print(rows[-1])


# ---------------------------------------------------------------------------
# batched hyperband rungs vs sequential trial loop
# ---------------------------------------------------------------------------

def _bench_batched_tuning(rows: list[str], verbose: bool, fast: bool) -> None:
    n, d, n_classes = 512, 16, 4
    k = n // 4
    max_budget = 9 if fast else 27
    rng = np.random.default_rng(1)
    feats = rng.normal(size=(n, d)).astype(np.float32)
    centers = rng.normal(size=(n_classes, d)).astype(np.float32) * 3.0
    labs = rng.integers(0, n_classes, size=n).astype(np.int64)
    feats += centers[labs]          # learnable structure so lr matters
    vx = jnp.asarray(feats[: n // 4])
    vy = jnp.asarray(labs[: n // 4])
    plan = build_selector("milo_fixed", features=feats, k=k).plan(0)
    xs = jnp.asarray(feats[plan.indices])
    ys = jnp.asarray(labs[plan.indices])
    w = jnp.asarray(plan.weights)

    def _trial_impl(lr, steps: int):
        params = init_mlp(jax.random.PRNGKey(0), d, n_classes, hidden=32)
        mom = jax.tree.map(jnp.zeros_like, params)

        def body(carry, _):
            p, m = carry
            _, g = jax.value_and_grad(weighted_nll)(p, xs, ys, w)
            return nesterov_update(p, m, g, lr), None

        (params, _), _ = jax.lax.scan(body, (params, mom), None, length=steps)
        return jnp.mean(jnp.argmax(mlp_logits(params, vx), -1) == vy)

    trial = jax.jit(_trial_impl, static_argnames="steps")
    trial_batch = jax.jit(
        lambda lrs, steps: jax.vmap(lambda lr: _trial_impl(lr, steps))(lrs),
        static_argnames="steps",
    )

    def objective(cfg: dict, budget: int) -> float:
        return float(trial(jnp.asarray(cfg["lr"], jnp.float32), budget * 4))

    def batched_objective(configs: list[dict], budget: int):
        lrs = jnp.asarray(stack_configs(configs)["lr"], jnp.float32)
        return np.asarray(trial_batch(lrs, budget * 4))

    space = {"lr": ("log", 1e-3, 0.5)}

    def run_seq():
        return hyperband(objective, RandomSearch(space, seed=0),
                         max_budget=max_budget, eta=3)

    def run_batched():
        return hyperband(None, RandomSearch(space, seed=0),
                         max_budget=max_budget, eta=3,
                         batched_objective=batched_objective)

    run_seq(), run_batched()  # warm every (rung-shape, budget) program
    t0 = time.perf_counter()
    res_seq = run_seq()
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_bat = run_batched()
    t_bat = time.perf_counter() - t0

    same_best = res_seq.best_config == res_bat.best_config
    same_stream = all(
        a["config"] == b["config"] and a["budget"] == b["budget"]
        and abs(a["score"] - b["score"]) < 1e-5
        for a, b in zip(res_seq.trials, res_bat.trials)
    ) and len(res_seq.trials) == len(res_bat.trials)
    rows.append(csv_row(
        "tuning/hyperband_sequential", t_seq * 1e6,
        f"trials={len(res_seq.trials)} best_lr={res_seq.best_config['lr']:.4f} "
        f"max_budget={max_budget}"))
    if verbose:
        print(rows[-1])
    rows.append(csv_row(
        "tuning/hyperband_batched", t_bat * 1e6,
        f"speedup_vs_sequential={t_seq / t_bat:.2f}x "
        f"identical_best={same_best} identical_trials={same_stream} "
        f"max_budget={max_budget}"))
    if verbose:
        print(rows[-1])


# ---------------------------------------------------------------------------
# MILO vs baselines (paper Fig. 6 / Tab. 5,7) — full mode only
# ---------------------------------------------------------------------------

def _bench_selector_baselines(rows: list[str], verbose: bool) -> None:
    ds = GaussianMixtureDataset(n=2000, n_classes=8, dim=24, seed=1)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    tx, ty = ds.features()[te], ds.y[te]
    epochs = 40

    # FULL skyline
    full = train_with_selector(feats, labs, build_selector("full", n=len(tr)),
                               epochs=epochs, test_x=tx, test_y=ty)
    rows.append(csv_row("training/full", full["train_time"] * 1e6,
                        f"acc={full['final_acc']:.4f} speedup=1.00"))
    if verbose:
        print(rows[-1])

    # proxy per-sample gradients for model-dependent baselines: last-layer
    # gradient of a probe model — recomputed at each refresh (their real cost)
    probe = init_mlp(jax.random.PRNGKey(9), feats.shape[1], int(labs.max()) + 1)

    def grad_fn():
        logits = mlp_logits(probe, jnp.asarray(feats))
        p = jax.nn.softmax(logits)
        onehot = jax.nn.one_hot(jnp.asarray(labs), logits.shape[-1])
        return np.asarray(p - onehot)  # last-layer grad proxy (CORDS-style)

    def val_grad_fn():
        logits = mlp_logits(probe, jnp.asarray(ds.features()[va]))
        p = jax.nn.softmax(logits)
        onehot = jax.nn.one_hot(jnp.asarray(ds.y[va]), logits.shape[-1])
        return np.asarray(p - onehot).mean(0)

    for frac in (0.1, 0.3):
        k = int(len(tr) * frac)
        pre_t0 = time.perf_counter()
        pre = MiloPreprocessor(subset_fraction=frac, n_sge_subsets=6, gram_block=512)
        md = pre.preprocess(feats, labs, jax.random.PRNGKey(0))
        preprocess_s = time.perf_counter() - pre_t0
        selectors = {
            "milo": build_selector("milo", metadata=md, total_epochs=epochs,
                                   kappa=1 / 6, R=1),
            "random": build_selector("random", n=len(tr), k=k, seed=0),
            "adaptive_random": build_selector("adaptive_random", n=len(tr), k=k,
                                              R=1, seed=0),
            "milo_fixed": build_selector("milo_fixed", features=feats, k=k),
            "craigpb_R10": build_selector("craig_pb", grad_fn=grad_fn, k=k, R=10),
            "gradmatchpb_R10": build_selector("gradmatch_pb", grad_fn=grad_fn, k=k, R=10),
            "glister_R10": build_selector("glister", grad_fn=grad_fn,
                                          val_grad_fn=val_grad_fn, k=k, R=10),
        }
        for name, sel in selectors.items():
            out = train_with_selector(feats, labs, sel, epochs=epochs,
                                      test_x=tx, test_y=ty)
            speedup = full["train_time"] / out["train_time"]
            degradation = full["final_acc"] - out["final_acc"]
            extra = f" preprocess_s={preprocess_s:.2f}" if name == "milo" else ""
            rows.append(csv_row(
                f"training/{name}/frac{frac}", out["train_time"] * 1e6,
                f"acc={out['final_acc']:.4f} speedup={speedup:.2f} "
                f"degradation={degradation:.4f} select_s={out['select_time']:.3f}{extra}"))
            if verbose:
                print(rows[-1])


def run(verbose: bool = True) -> list[str]:
    fast = os.environ.get("BENCH_FAST") == "1"
    rows: list[str] = []
    _bench_fused_training(rows, verbose, fast)
    _bench_batched_tuning(rows, verbose, fast)
    if not fast:
        _bench_selector_baselines(rows, verbose)
    return rows


if __name__ == "__main__":
    run()
