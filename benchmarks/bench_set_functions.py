"""Paper Fig. 4: model performance on fixed subsets chosen by maximizing each
set function, at 10% and 30% budgets.

Expected (paper): representation fns (graph-cut, facility location) win at
small budgets; diversity fns (disparity-min/sum) win at >=30%.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, train_with_selector
from repro.core import gram_matrix, greedy
from repro.core.submodular import REGISTRY
from repro.data.datasets import GaussianMixtureDataset


class _FixedSelector:
    def __init__(self, idx):
        self._idx = np.asarray(idx, np.int64)

    def indices_for_epoch(self, epoch):
        return self._idx


def run(verbose: bool = True) -> list[str]:
    ds = GaussianMixtureDataset(n=1500, n_classes=6, dim=24, seed=0)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    rows = []
    results = {}
    for frac in (0.1, 0.3):
        k = int(len(tr) * frac)
        for name, fn in REGISTRY.items():
            t0 = time.perf_counter()
            picks = []
            for c in np.unique(labs):  # class-wise, as the framework does
                loc = np.nonzero(labs == c)[0]
                K = gram_matrix(jnp.asarray(feats[loc]))
                kc = max(1, int(round(k * len(loc) / len(tr))))
                picks.extend(loc[np.asarray(greedy(fn, K, kc).indices)].tolist())
            sel_s = time.perf_counter() - t0
            out = train_with_selector(
                feats, labs, _FixedSelector(picks), epochs=40,
                test_x=ds.features()[te], test_y=ds.y[te],
            )
            results[(frac, name)] = out["final_acc"]
            rows.append(csv_row(
                f"set_fn/{name}/frac{frac}", sel_s * 1e6,
                f"acc={out['final_acc']:.4f}"))
            if verbose:
                print(rows[-1])
    # paper's qualitative claim at the small budget
    small_rep = max(results[(0.1, "graph_cut")], results[(0.1, "facility_location")])
    small_div = max(results[(0.1, "disparity_min")], results[(0.1, "disparity_sum")])
    rows.append(csv_row("set_fn/claim_small_budget_representation_wins", 0,
                        f"rep={small_rep:.4f} div={small_div:.4f} holds={small_rep >= small_div}"))
    if verbose:
        print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
