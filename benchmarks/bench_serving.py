"""Selection-as-a-service: a warm multi-tenant ``MiloServer`` vs N cold
``MiloSession``s running the same tuning workload.

The row tracked in ``BENCH_training.json``:

  * ``serving/concurrent_tuning`` — N tenants each run a full hyperband
    search (distinct search seeds) over the same dataset.  COLD: each
    tenant builds a fresh ``MiloSession`` and re-runs preprocessing before
    tuning — the pay-per-client baseline.  WARM: the tenants submit to one
    ``MiloServer`` whose artifact store, compiled-program pool and
    device-buffer registry were warmed before traffic arrived, so every
    request resolves preprocessing from memory and runs only the search.
    The row asserts three acceptance properties in its derived fields:
    ``speedup_vs_cold`` (>= 2x expected — pure preprocessing amortization,
    no thread-parallelism credit: process-global jit caches are warmed
    before BOTH phases, so cold pays only per-session preprocessing),
    ``identical_best`` (per-tenant best configs match bit-for-bit between
    phases — the server changes where work runs, never what it computes),
    and ``repeat_compiles`` (a warm repeat request records ZERO backend
    compiles, counted via jax.monitoring's compile-event stream).

``BENCH_FAST=1`` shrinks the dataset and client count (CI smoke).
"""
from __future__ import annotations

import os
import tempfile
import time

import jax

from benchmarks.common import csv_row
from repro.data.datasets import GaussianMixtureDataset
from repro.selection import MiloSession, MiloSessionConfig
from repro.serve import MiloClient, MiloServer

SPACE = {"lr": ("log", 3e-3, 0.3)}


def _count_backend_compiles(run) -> int:
    """Run ``run()`` under jax.monitoring's backend-compile event listener
    and return the number of programs it compiled (any thread — the serving
    workers included)."""
    compiles: list[str] = []

    def listener(name, duration, **kwargs):
        if name == "/jax/core/compile/backend_compile_duration":
            compiles.append(name)

    from jax._src import monitoring as _monitoring

    unregister = getattr(
        _monitoring, "_unregister_event_duration_listener_by_callback", None)
    jax.monitoring.register_event_duration_secs_listener(listener)
    try:
        run()
    finally:
        if unregister is not None:
            unregister(listener)
        else:  # pragma: no cover
            jax.monitoring.clear_event_listeners()
    return len(compiles)


def _bench_concurrent_tuning(rows: list[str], verbose: bool, fast: bool) -> None:
    n_clients = 3 if fast else 4
    n = 6000 if fast else 16000
    max_budget = 9
    ds = GaussianMixtureDataset(n=n, n_classes=6, dim=24, seed=3)
    tr, va, _ = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    vx, vy = ds.features()[va], ds.y[va]

    # a preprocessing-weighted workload (64-subset SGE bank, 20% budget):
    # the paper's regime, where the model-agnostic pass is the expensive
    # thing being amortized — at toy sizes preprocessing is nearly free and
    # serving could show no win no matter how good the caching is
    def cfg() -> MiloSessionConfig:
        return MiloSessionConfig(
            subset_fraction=0.2, n_sge_subsets=64, total_epochs=30,
            eval_every_epochs=10, gram_free=True, fused_training=True,
        )

    # worker pool sized to the machine: on a single-core box two workers
    # only interleave (GIL + dispatch contention) and slow BOTH requests;
    # the cold baseline is sequential, so this keeps the comparison honest
    workers = min(2, os.cpu_count() or 1)
    with MiloServer(cfg(), store_root=tempfile.mkdtemp(),
                    num_workers=workers) as server:
        # ALL warming happens up-front: the server's artifact + program pool,
        # and with it the process-global jit caches the cold sessions below
        # reuse.  Cold therefore pays only per-session preprocessing, never a
        # compile — a generous lower bound on a real cold start.
        t0 = time.perf_counter()
        server.warm(feats, labs, val_x=vx, val_y=vy, space=SPACE)
        t_setup = time.perf_counter() - t0

        # COLD: one fresh session per tenant, preprocessing re-run each time
        t0 = time.perf_counter()
        cold_best = []
        for i in range(n_clients):
            sess = MiloSession(cfg())
            sess.preprocess(feats, labs)
            res = sess.tune(feats, labs, vx, vy, SPACE,
                            max_budget=max_budget, eta=3, seed=1000 + i)
            cold_best.append(res.best_config)
        t_cold = time.perf_counter() - t0

        # WARM: the same N searches submitted concurrently to the one server
        t0 = time.perf_counter()
        rids = [
            MiloClient(server, tenant=f"tenant-{i}").submit_tune(
                feats, labs, vx, vy, SPACE,
                max_budget=max_budget, eta=3, seed=1000 + i)
            for i in range(n_clients)
        ]
        warm_best = [server.result(rid).best_config for rid in rids]
        t_warm = time.perf_counter() - t0

        identical = warm_best == cold_best

        # acceptance: a warm repeat request compiles NOTHING (lr is traced,
        # so even a fresh seed's lr draws reuse the warmed programs)
        compiles = _count_backend_compiles(
            lambda: MiloClient(server, tenant="repeat").tune(
                feats, labs, vx, vy, SPACE,
                max_budget=max_budget, eta=3, seed=1000))
        st = server.stats()

    rows.append(csv_row(
        "serving/concurrent_tuning", t_warm * 1e6,
        f"clients={n_clients} speedup_vs_cold={t_cold / t_warm:.2f}x "
        f"cold_s={t_cold:.2f} warm_s={t_warm:.2f} warm_setup_s={t_setup:.2f} "
        f"identical_best={identical} repeat_compiles={compiles} "
        f"store_builds={st['store']['builds']} store_hits={st['store']['hits']} "
        f"buffer_puts={st['buffers']['put_count']} "
        f"buffer_hits={st['buffers']['hits']}"))
    if verbose:
        print(rows[-1])


def run(verbose: bool = True) -> list[str]:
    fast = os.environ.get("BENCH_FAST") == "1"
    rows: list[str] = []
    _bench_concurrent_tuning(rows, verbose, fast)
    return rows


if __name__ == "__main__":
    run()
