"""Paper ablations: kappa curriculum (Tab. 13), R selection interval
(Tab. 14), hardness (EL2N-analog) of subsets per set function (Tab. 1/2),
WRE vs more-exploratory SGE variant (Tab. 15/16).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import csv_row, train_with_selector
from repro.core import CurriculumConfig, MiloPreprocessor, MiloSelector, gram_matrix, greedy
from repro.core.submodular import REGISTRY
from repro.data.datasets import GaussianMixtureDataset


def run(verbose: bool = True) -> list[str]:
    ds = GaussianMixtureDataset(n=1500, n_classes=6, dim=24, seed=3)
    tr, va, te = ds.split()
    feats, labs = ds.features()[tr], ds.y[tr]
    tx, ty = ds.features()[te], ds.y[te]
    epochs = 36
    rows = []

    pre = MiloPreprocessor(subset_fraction=0.1, n_sge_subsets=6, gram_block=512)
    md = pre.preprocess(feats, labs, jax.random.PRNGKey(0))

    # --- kappa ablation (Tab. 13): 0, 1/12, 1/6, 1/2, 1 ---------------------
    kappa_acc = {}
    for kappa in (0.0, 1 / 12, 1 / 6, 0.5, 1.0):
        sel = MiloSelector(md, CurriculumConfig(total_epochs=epochs, kappa=kappa, R=1))
        out = train_with_selector(feats, labs, sel, epochs=epochs, test_x=tx, test_y=ty)
        kappa_acc[kappa] = out["final_acc"]
        rows.append(csv_row(f"ablation/kappa_{kappa:.3f}", out["train_time"] * 1e6,
                            f"acc={out['final_acc']:.4f}"))
        if verbose:
            print(rows[-1])
    best_k = max(kappa_acc, key=kappa_acc.get)
    rows.append(csv_row("ablation/claim_kappa_interior_optimum", 0,
                        f"best_kappa={best_k:.3f} holds={0.0 < best_k < 1.0}"))

    # --- R ablation (Tab. 14): 1, 2, 5, 10 ----------------------------------
    r_acc = {}
    for R in (1, 2, 5, 10):
        sel = MiloSelector(md, CurriculumConfig(total_epochs=epochs, kappa=1 / 6, R=R))
        out = train_with_selector(feats, labs, sel, epochs=epochs, test_x=tx, test_y=ty)
        r_acc[R] = out["final_acc"]
        rows.append(csv_row(f"ablation/R_{R}", out["train_time"] * 1e6,
                            f"acc={out['final_acc']:.4f}"))
        if verbose:
            print(rows[-1])
    rows.append(csv_row("ablation/claim_R1_best", 0,
                        f"acc_R1={r_acc[1]:.4f} acc_R10={r_acc[10]:.4f} "
                        f"holds={r_acc[1] >= r_acc[10] - 0.01}"))

    # --- subset hardness per set function (Tab. 1/2, EL2N analog) ----------
    import jax.numpy as jnp

    for name, fn in REGISTRY.items():
        picks = []
        for c in np.unique(labs):
            loc = np.nonzero(labs == c)[0]
            K = gram_matrix(jnp.asarray(feats[loc]))
            picks.extend(loc[np.asarray(greedy(fn, K, max(1, len(loc) // 10)).indices)].tolist())
        hard = ds.is_hard[tr][picks].mean()
        rows.append(csv_row(f"ablation/hardness/{name}", 0, f"hard_frac={hard:.4f}"))
        if verbose:
            print(rows[-1])
    return rows


if __name__ == "__main__":
    run()
