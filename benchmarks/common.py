"""Shared benchmark machinery: a small classifier trained on per-epoch
``SelectionPlan`` streams (CPU-scale stand-in for the paper's ResNet/LSTM
downstream models).  Plan weights (CRAIG's γ, GRAD-MATCH's OMP coefficients)
are consumed by the loss; legacy selectors are adapted to uniform weights."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.classifier import (
    accuracy,
    init_mlp,
    mlp_logits,
    nesterov_update,
    weighted_nll,
)
from repro.selection import ensure_selector


@jax.jit
def _sgd_epoch(params, mom, x, y, w, lr):
    """One full pass over (x, y) as a single batch with Nesterov momentum,
    weighting each sample's NLL by its plan weight ``w`` (uniform = plain CE)."""

    l, g = jax.value_and_grad(weighted_nll)(params, x, y, w)
    params, mom = nesterov_update(params, mom, g, lr)
    return params, mom, l


def train_with_selector(
    features: np.ndarray,
    labels: np.ndarray,
    selector,
    *,
    epochs: int,
    test_x: np.ndarray,
    test_y: np.ndarray,
    lr: float = 0.05,  # paper's vision-setup value; 0.1 destabilizes the
                       # easy->hard transition with full-batch momentum
    seed: int = 0,
    eval_every: int = 1,
    sub_steps: int = 4,
) -> dict:
    """Train the bench MLP on selector-chosen subsets; track acc vs time.

    ``selector`` may implement either protocol (``plan`` or the legacy
    ``indices_for_epoch``); plan weights flow into the weighted loss.
    ``sub_steps`` full-batch passes per epoch over the selected subset keep
    the comparison faithful to minibatch epochs while staying jit-hot.
    """
    selector = ensure_selector(selector)
    xj, yj = jnp.asarray(features), jnp.asarray(labels)
    tx, ty = jnp.asarray(test_x), jnp.asarray(test_y)
    params = init_mlp(jax.random.PRNGKey(seed), features.shape[1], int(labels.max()) + 1)
    mom = jax.tree.map(jnp.zeros_like, params)
    curve = []
    # warm the jit caches outside the timed region — otherwise whichever
    # selector runs first in a comparison eats the compile time (including
    # the threefry kernels behind the WRE Gumbel draw at the final epoch)
    # validate once against this dataset (outside the timed loop): jnp gather
    # clamps out-of-range indices silently, so a selector built from a stale
    # artifact would otherwise train on wrong samples with no error
    warm = selector.plan(0).validate(len(features))
    if warm.phase in ("sge", "wre"):
        # curriculum selectors draw differently late in training (WRE Gumbel)
        # — compile that too; for R-windowed model-dependent selectors the
        # same call would force a full re-selection that epoch 0 discards
        _ = selector.plan(epochs - 1).validate(len(features))
    # unconditional on purpose (unlike MiloSession.train): the timed loop must
    # charge windowed selectors their epoch-0 selection, exactly as the seed
    # code's `epoch % R == 0` recompute did — that cost IS the benchmark's
    # argument for MILO's preprocessing decoupling
    getattr(selector, "reset_cache", lambda: None)()
    _p, _m, _ = _sgd_epoch(params, mom, xj[warm.indices], yj[warm.indices],
                           jnp.asarray(warm.weights), 0.0)
    jax.block_until_ready(accuracy(_p, tx, ty))
    t0 = time.perf_counter()
    select_time = 0.0
    for epoch in range(epochs):
        ts = time.perf_counter()
        plan = selector.plan(epoch)
        select_time += time.perf_counter() - ts
        xs, ys = xj[plan.indices], yj[plan.indices]
        ws = jnp.asarray(plan.weights)
        # float(): keep the lr a weak-typed python scalar — an np.float64
        # here silently changes the jit cache key vs the warm-up call and
        # recompiles inside the timed region
        cos = float(0.5 * (1 + np.cos(np.pi * epoch / max(epochs - 1, 1))))
        for _ in range(sub_steps):
            params, mom, l = _sgd_epoch(params, mom, xs, ys, ws, lr * cos)
        if epoch % eval_every == 0 or epoch == epochs - 1:
            acc = float(accuracy(params, tx, ty))
            curve.append({"epoch": epoch, "acc": acc,
                          "wall": time.perf_counter() - t0})
    return {
        "final_acc": curve[-1]["acc"],
        "best_acc": max(c["acc"] for c in curve),
        "train_time": time.perf_counter() - t0,
        "select_time": select_time,
        "curve": curve,
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
