"""Shared benchmark machinery: a small classifier trained on per-epoch index
streams (CPU-scale stand-in for the paper's ResNet/LSTM downstream models)."""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense, init_dense


def init_mlp(key, d_in: int, n_classes: int, d_hidden: int = 64) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": init_dense(k1, d_in, d_hidden, jnp.float32), "b1": jnp.zeros((d_hidden,)),
        "w2": init_dense(k2, d_hidden, d_hidden, jnp.float32), "b2": jnp.zeros((d_hidden,)),
        "w3": init_dense(k3, d_hidden, n_classes, jnp.float32), "b3": jnp.zeros((n_classes,)),
    }


def mlp_logits(p, x):
    h = jax.nn.relu(dense(x, p["w1"]) + p["b1"])
    h = jax.nn.relu(dense(h, p["w2"]) + p["b2"])
    return dense(h, p["w3"]) + p["b3"]


@jax.jit
def _sgd_epoch(params, mom, x, y, lr):
    """One full pass over (x, y) as a single batch with Nesterov momentum."""

    def loss(p):
        lp = jax.nn.log_softmax(mlp_logits(p, x))
        return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))

    l, g = jax.value_and_grad(loss)(params)
    mom = jax.tree.map(lambda m, gg: 0.9 * m + gg, mom, g)
    params = jax.tree.map(lambda p, m, gg: p - lr * (gg + 0.9 * m), params, mom, g)
    return params, mom, l


@jax.jit
def accuracy(params, x, y):
    return jnp.mean(jnp.argmax(mlp_logits(params, x), -1) == y)


def train_with_selector(
    features: np.ndarray,
    labels: np.ndarray,
    selector,
    *,
    epochs: int,
    test_x: np.ndarray,
    test_y: np.ndarray,
    lr: float = 0.05,  # paper's vision-setup value; 0.1 destabilizes the
                       # easy->hard transition with full-batch momentum
    seed: int = 0,
    eval_every: int = 1,
    sub_steps: int = 4,
) -> dict:
    """Train the bench MLP on selector-chosen subsets; track acc vs time.

    ``sub_steps`` full-batch passes per epoch over the selected subset keep
    the comparison faithful to minibatch epochs while staying jit-hot.
    """
    xj, yj = jnp.asarray(features), jnp.asarray(labels)
    tx, ty = jnp.asarray(test_x), jnp.asarray(test_y)
    params = init_mlp(jax.random.PRNGKey(seed), features.shape[1], int(labels.max()) + 1)
    mom = jax.tree.map(jnp.zeros_like, params)
    curve = []
    # warm the jit caches outside the timed region — otherwise whichever
    # selector runs first in a comparison eats the compile time (including
    # the threefry kernels behind the WRE Gumbel draw at the final epoch)
    warm_idx = np.asarray(selector.indices_for_epoch(0))
    _ = np.asarray(selector.indices_for_epoch(epochs - 1))
    if hasattr(selector, "_cache_epoch"):
        selector._cache_epoch = -1
    _p, _m, _ = _sgd_epoch(params, mom, xj[warm_idx], yj[warm_idx], 0.0)
    jax.block_until_ready(accuracy(_p, tx, ty))
    t0 = time.perf_counter()
    select_time = 0.0
    for epoch in range(epochs):
        ts = time.perf_counter()
        idx = np.asarray(selector.indices_for_epoch(epoch))
        select_time += time.perf_counter() - ts
        xs, ys = xj[idx], yj[idx]
        # float(): keep the lr a weak-typed python scalar — an np.float64
        # here silently changes the jit cache key vs the warm-up call and
        # recompiles inside the timed region
        cos = float(0.5 * (1 + np.cos(np.pi * epoch / max(epochs - 1, 1))))
        for _ in range(sub_steps):
            params, mom, l = _sgd_epoch(params, mom, xs, ys, lr * cos)
        if epoch % eval_every == 0 or epoch == epochs - 1:
            acc = float(accuracy(params, tx, ty))
            curve.append({"epoch": epoch, "acc": acc,
                          "wall": time.perf_counter() - t0})
    return {
        "final_acc": curve[-1]["acc"],
        "best_acc": max(c["acc"] for c in curve),
        "train_time": time.perf_counter() - t0,
        "select_time": select_time,
        "curve": curve,
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
